"""Markdown link + code-reference check for the docs tree (stdlib-only).

Scans README.md, docs/*.md, and the other top-level *.md files for inline
markdown links/images `[text](target)` and verifies every **relative**
target resolves to an existing file or directory (anchors are stripped;
http(s)/mailto targets are skipped — no network in CI). Also checks that
intra-repo targets don't escape the repo root.

Additionally, for files under docs/ only, every inline backtick code span
that *looks like a repo file path* — contains at least one "/" and ends in
a known source extension, e.g. `src/repro/core/lists.py` or
`kernels/ops.py` — must resolve against the repo root, src/, or
src/repro/ (brace groups like `serving/{batcher,loop}.py` are expanded;
a trailing `::symbol` test-reference suffix is stripped). This keeps prose
like "the scan driver (core/ivf.py)" from silently rotting when modules
move. Bare names without a slash are never checked — too many false
positives.

    python tools/check_docs_links.py          # exit 1 + report on dead refs
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# inline links/images; [1] skips fenced code via the scrub below
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

# a code span is treated as a path reference iff it has >= 1 "/" and one of
# these extensions; anything else (dotted API paths, shell fragments) is prose
CODE_REF_EXTS = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt",
                 ".csv", ".sh")
# roots a doc code reference may be relative to, tried in order
CODE_REF_ROOTS = (ROOT, ROOT / "src", ROOT / "src" / "repro")
BRACE_RE = re.compile(r"\{([^{}]*)\}")


def md_files() -> list[pathlib.Path]:
    files = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.is_file()]


def expand_braces(token: str) -> list[str]:
    """`serving/{batcher,loop}.py` -> [serving/batcher.py, serving/loop.py]."""
    m = BRACE_RE.search(token)
    if not m:
        return [token]
    head, tail = token[:m.start()], token[m.end():]
    out = []
    for alt in m.group(1).split(","):
        out.extend(expand_braces(head + alt.strip() + tail))
    return out


def code_ref_paths(span: str) -> list[str]:
    """Path tokens a backtick code span refers to ([] = not a path ref)."""
    token = span.strip().split("::", 1)[0]  # drop `path.py::test_name`
    if "/" not in token or not token.endswith(CODE_REF_EXTS):
        return []
    # reject spans that are clearly commands/prose, not a lone path
    if any(c in token for c in " <>|*?$"):
        return []
    return expand_braces(token)


def resolve_code_ref(path: str) -> bool:
    for root in CODE_REF_ROOTS:
        cand = (root / path).resolve()
        if cand != ROOT and ROOT not in cand.parents:
            return False  # escapes the repo — never OK
        if cand.exists():
            return True
    return False


def check_file(md: pathlib.Path) -> list[str]:
    text = FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    rel = md.relative_to(ROOT)
    errors = []
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure-anchor link
            continue
        resolved = (md.parent / path).resolve()
        if resolved != ROOT and ROOT not in resolved.parents:
            errors.append(f"{rel}: link escapes repo root: {target}")
        elif not resolved.exists():
            errors.append(f"{rel}: dead link: {target}")
    # code references: docs/ only (top-level files quote external paths)
    if (ROOT / "docs") in md.parents:
        for m in CODE_SPAN_RE.finditer(text):
            for path in code_ref_paths(m.group(1)):
                if not resolve_code_ref(path):
                    errors.append(f"{rel}: dead code reference: `{m.group(1)}`")
    return errors


def main() -> int:
    files = md_files()
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(f"ERROR {e}", file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAILED' if errors else 'ok'} ({len(errors)} dead refs)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
