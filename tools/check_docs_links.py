"""Markdown link check for the docs tree (stdlib-only; CI docs job).

Scans README.md, docs/*.md, and the other top-level *.md files for inline
markdown links/images `[text](target)` and verifies every **relative**
target resolves to an existing file or directory (anchors are stripped;
http(s)/mailto targets are skipped — no network in CI). Also checks that
intra-repo targets don't escape the repo root.

    python tools/check_docs_links.py          # exit 1 + report on dead links
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# inline links/images; [1] skips fenced code via the scrub below
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files() -> list[pathlib.Path]:
    files = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.is_file()]


def check_file(md: pathlib.Path) -> list[str]:
    text = FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    errors = []
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure-anchor link
            continue
        resolved = (md.parent / path).resolve()
        rel = md.relative_to(ROOT)
        if resolved != ROOT and ROOT not in resolved.parents:
            errors.append(f"{rel}: link escapes repo root: {target}")
        elif not resolved.exists():
            errors.append(f"{rel}: dead link: {target}")
    return errors


def main() -> int:
    files = md_files()
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(f"ERROR {e}", file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAILED' if errors else 'ok'} ({len(errors)} dead links)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
