"""Non-blocking scan-traffic regression check for the CI bench smoke.

Diffs the ``bytes_accessed`` fields of a freshly produced BENCH_kernels.json
against the committed baseline and emits a GitHub Actions ``::warning``
annotation for every record whose scan-stage HBM traffic grew more than the
threshold (default 10%). Always exits 0 — traffic is a trend to watch, not
a gate (shapes and backends legitimately change); the annotation puts the
regression in the job summary where a reviewer sees it.

Usage:
    python tools/check_bench_traffic.py --baseline /tmp/baseline.json \
        --fresh BENCH_kernels.json [--threshold 0.10]
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_records(path: str) -> dict[tuple, dict]:
    """Index records by identity key; records without bytes are skipped."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::notice::traffic check skipped: cannot read {path} ({e})")
        return {}
    out = {}
    for rec in data.get("records", []):
        if rec.get("bytes_accessed") is None:
            continue
        key = (rec.get("kernel"), rec.get("impl"), rec.get("backend"),
               rec.get("G"), rec.get("Q"), rec.get("P"), rec.get("cap"),
               rec.get("M"), rec.get("k"), rec.get("r"), rec.get("D"),
               rec.get("N"))
        out[key] = rec
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_kernels.json (pre-run copy)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_kernels.json produced by this run")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative growth that triggers a warning")
    args = ap.parse_args(argv)

    base = _load_records(args.baseline)
    fresh = _load_records(args.fresh)
    if not base or not fresh:
        print("::notice::traffic check: nothing to compare")
        return 0

    grew = checked = 0
    for key, rec in sorted(fresh.items(), key=str):
        old = base.get(key)
        if old is None or not old["bytes_accessed"]:
            continue
        checked += 1
        ratio = rec["bytes_accessed"] / old["bytes_accessed"]
        label = "/".join(str(k) for k in key if k is not None)
        if ratio > 1.0 + args.threshold:
            grew += 1
            print(f"::warning title=scan traffic regression::{label}: "
                  f"bytes_accessed {old['bytes_accessed']:.0f} -> "
                  f"{rec['bytes_accessed']:.0f} ({(ratio - 1) * 100:+.1f}%)")
        else:
            print(f"ok {label}: {old['bytes_accessed']:.0f} -> "
                  f"{rec['bytes_accessed']:.0f} ({(ratio - 1) * 100:+.1f}%)")
    print(f"traffic check: {checked} records compared, {grew} grew "
          f">{args.threshold * 100:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
