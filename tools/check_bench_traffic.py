"""Non-blocking scan-traffic regression check for the CI bench smoke.

Diffs the ``bytes_accessed`` fields of a freshly produced BENCH_kernels.json
against the committed baseline and emits a GitHub Actions ``::warning``
annotation for every record whose scan-stage HBM traffic grew more than the
threshold (default 10%). Also watches the durability records
(docs/persistence.md): a ``replication_lag`` record whose post-poll lag is
nonzero means a standby stopped catching up in one round-trip, and a
``checkpoint_bytes`` delta record whose write_ratio grew more than the
threshold means the content-hash dedup stopped reusing parent segments.
Also watches the anytime serving frontier
(``serve_frontier`` records, docs/anytime.md): a warning fires when an
adaptive operating point's recall@1 drops more than 1% against the
committed baseline at the matched point, or when no adaptive point beats
the fixed-budget baseline's p99 at matched recall anymore. Always exits
0 — traffic and frontier shape are trends to watch, not gates (shapes,
machines and backends legitimately change); the annotations put the
regression in the job summary where a reviewer sees it.

Usage:
    python tools/check_bench_traffic.py --baseline /tmp/baseline.json \
        --fresh BENCH_kernels.json [--threshold 0.10]
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_json(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::notice::traffic check skipped: cannot read {path} ({e})")
        return {}


def _index_records(data: dict) -> dict[tuple, dict]:
    """Index records by identity key; records without bytes are skipped."""
    out = {}
    for rec in data.get("records", []):
        if rec.get("bytes_accessed") is None:
            continue
        key = (rec.get("kernel"), rec.get("impl"), rec.get("backend"),
               rec.get("G"), rec.get("Q"), rec.get("P"), rec.get("cap"),
               rec.get("M"), rec.get("k"), rec.get("r"), rec.get("D"),
               rec.get("N"))
        out[key] = rec
    return out


def _frontier_points(data: dict) -> dict[tuple, dict]:
    """serve_frontier records keyed by operating point (policy, tau, np)."""
    return {(r.get("probe_policy"), r.get("margin_tau"), r.get("nprobe_max")):
            r for r in data.get("records", [])
            if r.get("kernel") == "serve_frontier"}


def check_frontier(base: dict, fresh: dict, recall_drop: float = 0.01) -> int:
    """Warn when the anytime frontier degrades vs the committed baseline.

    Two non-blocking signals (p99 itself is machine-dependent wall clock, so
    absolute latency is never diffed across runs):
      - an operating point's recall@1 fell more than ``recall_drop`` vs the
        committed record for the same (policy, tau, nprobe_max);
      - within the fresh run alone, no adaptive point reaches the fixed
        nprobe_max baseline's recall@1 at strictly lower p99 (the
        serve_bench acceptance property stopped holding).
    Returns the number of warnings emitted.
    """
    bpts, fpts = _frontier_points(base), _frontier_points(fresh)
    if not fpts:
        return 0
    warned = 0
    for key, rec in sorted(fpts.items(), key=str):
        old = bpts.get(key)
        if old is None or old.get("recall_at_1") is None:
            continue
        drop = old["recall_at_1"] - rec.get("recall_at_1", 0.0)
        label = "/".join(str(k) for k in key if k is not None)
        if drop > recall_drop:
            warned += 1
            print(f"::warning title=anytime frontier regression::{label}: "
                  f"recall@1 {old['recall_at_1']:.3f} -> "
                  f"{rec['recall_at_1']:.3f} (-{drop * 100:.1f}%)")
        else:
            print(f"ok frontier {label}: recall@1 "
                  f"{old['recall_at_1']:.3f} -> {rec['recall_at_1']:.3f}")
    fixed = [r for (p, _, _), r in fpts.items() if p == "fixed"]
    adaptive = [r for (p, _, _), r in fpts.items() if p == "margin"]
    if fixed and adaptive:
        baseline = max(fixed, key=lambda r: r.get("nprobe_max") or 0)
        wins = [r for r in adaptive
                if r.get("recall_at_1", 0.0) >= baseline.get("recall_at_1", 0.0)
                and r.get("p99_us", float("inf")) < baseline.get("p99_us", 0.0)]
        if not wins:
            warned += 1
            print("::warning title=anytime frontier regression::no adaptive "
                  "point beats the fixed baseline's p99 at matched recall@1 "
                  f"(baseline {baseline.get('impl')}: "
                  f"recall@1={baseline.get('recall_at_1'):.3f}, "
                  f"p99_us={baseline.get('p99_us'):.0f})")
        else:
            print(f"ok frontier acceptance: {len(wins)} adaptive point(s) "
                  "beat the fixed baseline")
    return warned


def check_durability(base: dict, fresh: dict, threshold: float) -> int:
    """Warn on replication lag or delta-checkpoint dedup regressions.

    Both are shape properties, not wall clock, so they diff cleanly
    across machines: a caught-up standby has ``lag_seqs == 0`` after its
    poll whatever the hardware, and the delta checkpoint's write_ratio
    depends only on which segments the workload dirtied. Non-blocking,
    like everything else here.
    """
    warned = 0
    for rec in fresh.get("records", []):
        if rec.get("metric") != "replication_lag":
            continue
        lag = rec.get("lag_seqs", 0)
        if lag and lag > 0:
            warned += 1
            print("::warning title=replication lag::standby still "
                  f"{lag} seqs behind after its poll "
                  f"(lag_s={rec.get('lag_s')})")
        else:
            print(f"ok replication: standby caught up "
                  f"({rec.get('lag_seqs_before_poll', '?')} seqs drained)")
    fresh_delta = next((r for r in fresh.get("records", [])
                        if r.get("metric") == "checkpoint_bytes"
                        and r.get("mode") == "delta"), None)
    base_delta = next((r for r in base.get("records", [])
                       if r.get("metric") == "checkpoint_bytes"
                       and r.get("mode") == "delta"), None)
    if fresh_delta and base_delta and base_delta.get("write_ratio"):
        old, new = base_delta["write_ratio"], fresh_delta.get("write_ratio", 1.0)
        if new > old * (1.0 + threshold):
            warned += 1
            print("::warning title=delta checkpoint regression::"
                  f"write_ratio {old:.3f} -> {new:.3f} — the checkpoint is "
                  "rewriting segments the parent already holds")
        else:
            print(f"ok delta checkpoint: write_ratio {old:.3f} -> {new:.3f}")
    return warned


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_kernels.json (pre-run copy)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_kernels.json produced by this run")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative growth that triggers a warning")
    args = ap.parse_args(argv)

    base_data = _load_json(args.baseline)
    fresh_data = _load_json(args.fresh)
    base = _index_records(base_data)
    fresh = _index_records(fresh_data)
    if not base or not fresh:
        print("::notice::traffic check: nothing to compare")
        check_frontier(base_data, fresh_data)
        check_durability(base_data, fresh_data, args.threshold)
        return 0

    grew = checked = 0
    for key, rec in sorted(fresh.items(), key=str):
        old = base.get(key)
        if old is None or not old["bytes_accessed"]:
            continue
        checked += 1
        ratio = rec["bytes_accessed"] / old["bytes_accessed"]
        label = "/".join(str(k) for k in key if k is not None)
        if ratio > 1.0 + args.threshold:
            grew += 1
            print(f"::warning title=scan traffic regression::{label}: "
                  f"bytes_accessed {old['bytes_accessed']:.0f} -> "
                  f"{rec['bytes_accessed']:.0f} ({(ratio - 1) * 100:+.1f}%)")
        else:
            print(f"ok {label}: {old['bytes_accessed']:.0f} -> "
                  f"{rec['bytes_accessed']:.0f} ({(ratio - 1) * 100:+.1f}%)")
    print(f"traffic check: {checked} records compared, {grew} grew "
          f">{args.threshold * 100:.0f}%")
    check_frontier(base_data, fresh_data)
    check_durability(base_data, fresh_data, args.threshold)
    return 0


if __name__ == "__main__":
    sys.exit(main())
