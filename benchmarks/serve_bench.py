"""Serving-layer benchmark: offered-load sweep through the micro-batcher.

Measures what the kernel benchmarks cannot: end-to-end request latency when
queries arrive one at a time and the ``repro.serving`` loop must batch them
dynamically. For each offered load (Poisson arrivals at a target QPS) we
drive N requests through ``ServingLoop`` -> ``SearchEngine.search_jit`` and
report:

  - p50 / p99 submit->result latency (the ``us_per_call`` CSV column is p50);
  - achieved throughput (completed requests / wall time);
  - mean batch occupancy (real rows / dispatched rows — how well the
    batcher fills its shape buckets at that load);
  - fused-jit compiles observed during the timed run (should be 0 after
    warmup: steady-state serving never recompiles).

Also emits one ``serve_fused_speedup_{impl}`` row per grouped-scan kernel
impl (ref / select / mxu / stream / auto) comparing staged ``search`` vs
fused ``search_jit`` dispatch latency at Q=1 — separating the kernel win
(which impl scans fastest; ``stream`` is the gather-free in-kernel DMA
path) from the dispatch win (tracing the whole pipeline into a single XLA
program). The stream-vs-ref fused delta is the end-to-end cost/benefit of
removing the gathered candidate pool at serving batch sizes. A matching
``serve_fused_speedup_rerank_{impl}`` row per exact-re-rank impl
(gathered / stream / auto) isolates stage 3's gather-free win the same way.
"""
from __future__ import annotations

import math
import time

import jax
import numpy as np

from benchmarks import common
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine
from repro.kernels.ops import RERANK_IMPLS, SCAN_IMPLS
from repro.serving import ServingLoop


def _percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p))


def _drive(loop: ServingLoop, queries: np.ndarray, qps: float,
           n_requests: int, rng: np.random.Generator) -> dict:
    """Submit Poisson arrivals at ``qps``; return latency/occupancy numbers."""
    m0 = loop.metrics()
    futs = []
    t_start = time.monotonic()
    t_next = t_start
    for i in range(n_requests):
        now = time.monotonic()
        if t_next > now:
            time.sleep(t_next - now)
        futs.append(loop.submit(queries[i % queries.shape[0]], k=10,
                                tenant=f"tenant{i % 4}"))
        t_next += rng.exponential(1.0 / qps)
    lats = [f.result(timeout=60).latency_s for f in futs]
    wall = time.monotonic() - t_start
    m1 = loop.metrics()
    rows = m1.rows_served - m0.rows_served
    padded = m1.rows_padded - m0.rows_padded
    return {
        "p50_s": _percentile(lats, 50),
        "p99_s": _percentile(lats, 99),
        "qps_achieved": n_requests / wall,
        "occupancy": rows / (rows + padded) if rows + padded else 0.0,
        "compiles": m1.compiles - m0.compiles,
    }


def main() -> None:
    n_requests = 64 if common.SMOKE else 256
    ds = vectors.make_sift_like(n=common.N_BASE, nt=common.N_TRAIN,
                                nq=max(common.N_QUERY, 128), d=64)
    nlist = max(16, int(math.sqrt(ds.base.shape[0])))
    engine = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                m=8, nlist=nlist, coarse_iters=8, pq_iters=8)
    rng = np.random.default_rng(0)
    queries = np.asarray(ds.queries, np.float32)

    # staged-vs-fused single-dispatch latency at Q=1 (the small-batch regime
    # the fused path exists for), per grouped-scan kernel impl — so the
    # serving numbers separate the kernel win from the dispatch win
    q1 = queries[:1]
    t_fused = None
    for impl in SCAN_IMPLS:
        eng_i = SearchEngine(engine.index, base=engine.base,
                             config=engine.config._replace(scan_impl=impl))
        t_s = common.time_call(
            lambda e=eng_i: e.search(q1, 10, rerank_mult=4).ids, iters=5)
        t_f = common.time_call(
            lambda e=eng_i: e.search_jit(q1, 10, rerank_mult=4).ids, iters=5)
        common.emit(f"serve_fused_speedup_{impl}", t_f,
                    f"staged_us={t_s * 1e6:.1f};"
                    f"speedup={t_s / max(t_f, 1e-12):.2f}x")
        if impl == engine.config.scan_impl:
            t_fused = t_f
    assert t_fused is not None  # SCAN_IMPLS always contains the default impl

    # same decomposition for stage 3: staged vs fused per exact-re-rank impl
    # (the gathered-vs-stream fused delta is the end-to-end cost/benefit of
    # removing the candidate-row gather at serving batch sizes)
    for impl in RERANK_IMPLS:
        eng_i = SearchEngine(engine.index, base=engine.base,
                             config=engine.config._replace(rerank_impl=impl))
        t_s = common.time_call(
            lambda e=eng_i: e.search(q1, 10, rerank_mult=4).ids, iters=5)
        t_f = common.time_call(
            lambda e=eng_i: e.search_jit(q1, 10, rerank_mult=4).ids, iters=5)
        common.emit(f"serve_fused_speedup_rerank_{impl}", t_f,
                    f"staged_us={t_s * 1e6:.1f};"
                    f"speedup={t_s / max(t_f, 1e-12):.2f}x")

    loop = ServingLoop(engine, rerank_mult=4, max_wait_s=0.005)
    loop.start(warmup=True)
    try:
        # calibrate offered loads off the fused dispatch time so the sweep
        # spans under- and over-subscribed regimes on any machine
        base_qps = 1.0 / max(t_fused, 1e-6)
        for label, qps in (("light", 0.25 * base_qps),
                           ("heavy", 2.0 * base_qps)):
            r = _drive(loop, queries, qps, n_requests, rng)
            common.emit(
                f"serve_load_{label}", r["p50_s"],
                f"p99_us={r['p99_s'] * 1e6:.1f};"
                f"qps={r['qps_achieved']:.0f};"
                f"occupancy={r['occupancy']:.2f};"
                f"compiles={r['compiles']}")
    finally:
        loop.stop()


if __name__ == "__main__":
    main()
