"""Serving-layer benchmark: offered-load sweep through the micro-batcher.

Measures what the kernel benchmarks cannot: end-to-end request latency when
queries arrive one at a time and the ``repro.serving`` loop must batch them
dynamically. For each offered load (Poisson arrivals at a target QPS) we
drive N requests through ``ServingLoop`` -> ``SearchEngine.search_jit`` and
report:

  - p50 / p99 submit->result latency (the ``us_per_call`` CSV column is p50);
  - achieved throughput (completed requests / wall time);
  - mean batch occupancy (real rows / dispatched rows — how well the
    batcher fills its shape buckets at that load);
  - fused-jit compiles observed during the timed run (should be 0 after
    warmup: steady-state serving never recompiles).

Also emits one ``serve_fused_speedup_{impl}`` row per grouped-scan kernel
impl (ref / select / mxu / stream / auto) comparing staged ``search`` vs
fused ``search_jit`` dispatch latency at Q=1 — separating the kernel win
(which impl scans fastest; ``stream`` is the gather-free in-kernel DMA
path) from the dispatch win (tracing the whole pipeline into a single XLA
program). The stream-vs-ref fused delta is the end-to-end cost/benefit of
removing the gathered candidate pool at serving batch sizes. A matching
``serve_fused_speedup_rerank_{impl}`` row per exact-re-rank impl
(gathered / stream / auto) isolates stage 3's gather-free win the same way.

Finally, ``frontier()`` sweeps the anytime operating points — fixed nprobe
budgets vs the margin policy at several tau (docs/anytime.md) — under
identical Poisson traffic and records the recall@1-vs-p99 frontier into
``BENCH_kernels.json`` as ``serve_frontier`` records.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine
from repro.kernels.ops import RERANK_IMPLS, SCAN_IMPLS
from repro.serving import ServingLoop

KERNELS_JSON = os.environ.get("REPRO_BENCH_KERNELS", "BENCH_kernels.json")


def _percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p))


def _drive(loop: ServingLoop, queries: np.ndarray, qps: float,
           n_requests: int, rng: np.random.Generator) -> dict:
    """Submit Poisson arrivals at ``qps``; return latency/occupancy numbers."""
    m0 = loop.metrics()
    futs = []
    t_start = time.monotonic()
    t_next = t_start
    for i in range(n_requests):
        now = time.monotonic()
        if t_next > now:
            time.sleep(t_next - now)
        futs.append(loop.submit(queries[i % queries.shape[0]], k=10,
                                tenant=f"tenant{i % 4}"))
        t_next += rng.exponential(1.0 / qps)
    lats = [f.result(timeout=60).latency_s for f in futs]
    wall = time.monotonic() - t_start
    m1 = loop.metrics()
    rows = m1.rows_served - m0.rows_served
    padded = m1.rows_padded - m0.rows_padded
    return {
        "p50_s": _percentile(lats, 50),
        "p99_s": _percentile(lats, 99),
        "qps_achieved": n_requests / wall,
        "occupancy": rows / (rows + padded) if rows + padded else 0.0,
        "compiles": m1.compiles - m0.compiles,
    }


def _merge_frontier(new: list[dict]) -> None:
    """Append frontier records into BENCH_kernels.json without clobbering
    the kernel sweeps (kernel_bench.main overwrites the file; run.py runs
    serve_bench after it)."""
    try:
        with open(KERNELS_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = {"schema": "repro.kernel_bench/v1", "records": []}
    kept = [r for r in data.get("records", [])
            if r.get("kernel") != "serve_frontier"]
    data["records"] = kept + new
    with open(KERNELS_JSON, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def frontier() -> list[dict]:
    """Recall-vs-p99 frontier over (probe_policy, margin_tau, nprobe_max).

    The anytime claim (docs/anytime.md) is not "margin pruning is fast" —
    it is that on margin-skewed traffic the adaptive policy reaches the
    fixed-nprobe baseline's *recall* at lower tail latency, because easy
    queries stop paying the worst-case probe budget. So the sweep drives
    identical Poisson traffic (clustered queries, real margins) through one
    ``ServingLoop`` per operating point — fixed at several nprobe budgets,
    margin at several tau — and records (recall@1, p50, p99, pruned/skipped
    counters) per point into BENCH_kernels.json as ``serve_frontier``
    records. Acceptance: >= 1 adaptive point reaches the fixed
    nprobe_max baseline's recall@1 at strictly lower p99
    (``tools/check_bench_traffic.py`` watches the frontier across PRs).
    """
    n_requests = 32 if common.SMOKE else 96
    nprobe_max = 16
    # clustered base + noisy queries: easy queries have one dominant list
    # (the margin prunes their probe budget to ~1-2), hard ones genuinely
    # need several — the mix where a fixed budget wastes work on the easy
    # majority. Sized so kernel work dominates per-dispatch host overhead.
    ds = vectors.make_sift_like(n=40_000, nt=6_000, nq=64, d=32, ncl=32,
                                seed=7, query_noise=1.0)
    engine = SearchEngine.build(
        jax.random.PRNGKey(0), ds.train, ds.base, m=8, nlist=32,
        coarse_iters=6, pq_iters=6,
        config=EngineConfig(nprobe=nprobe_max, rerank_mult=2,
                            scan_impl="stream"))
    gt1 = np.asarray(ds.gt_ids)[:, 0]
    queries = np.asarray(ds.queries, np.float32)
    t_base = common.time_call(
        lambda: engine.search_jit(queries[:1], 10).ids, iters=3)
    # mostly-idle offered load, identical for every point: per-request
    # latency then reflects dispatch cost, not queue-drain backlog
    qps = 0.25 / max(t_base, 1e-6)

    points = [
        ("fixed", None, 2), ("fixed", None, 4), ("fixed", None, nprobe_max),
        ("margin", 0.25, nprobe_max), ("margin", 1.0, nprobe_max),
        ("margin", 4.0, nprobe_max),
    ]
    records = []
    for policy, tau, nprobe in points:
        cfg = engine.config._replace(nprobe=nprobe, probe_policy=policy,
                                     early_exit=(policy == "margin"))
        eng_i = SearchEngine(engine.index, base=engine.base, config=cfg)
        loop = ServingLoop(eng_i, max_wait_s=0.005,
                           margin_tau=tau if policy == "margin" else None)
        loop.start(warmup=True)
        try:
            rng = np.random.default_rng(1)  # same arrival process per point
            m0 = loop.metrics()
            futs, t_next = [], time.monotonic()
            for i in range(n_requests):
                now = time.monotonic()
                if t_next > now:
                    time.sleep(t_next - now)
                futs.append((i % queries.shape[0],
                             loop.submit(queries[i % queries.shape[0]],
                                         k=10)))
                t_next += rng.exponential(1.0 / qps)
            lats, hits = [], []
            for qi, f in futs:
                res = f.result(timeout=120)
                lats.append(res.latency_s)
                hits.append(float(res.ids[0] == gt1[qi]))
            m1 = loop.metrics()
        finally:
            loop.stop()
        label = (f"{policy}_np{nprobe}" if policy == "fixed"
                 else f"{policy}_tau{tau}_np{nprobe}")
        rec = {"kernel": "serve_frontier", "impl": label,
               "probe_policy": policy, "margin_tau": tau,
               "nprobe_max": nprobe, "recall_at_1": float(np.mean(hits)),
               "p50_us": _percentile(lats, 50) * 1e6,
               "p99_us": _percentile(lats, 99) * 1e6,
               "lists_pruned": m1.lists_pruned - m0.lists_pruned,
               "tiles_skipped": m1.tiles_skipped - m0.tiles_skipped,
               "n_requests": n_requests,
               "backend": jax.default_backend()}
        records.append(rec)
        common.emit(f"serve_frontier_{label}", rec["p50_us"] / 1e6,
                    f"p99_us={rec['p99_us']:.1f};"
                    f"recall@1={rec['recall_at_1']:.3f};"
                    f"lists_pruned={rec['lists_pruned']};"
                    f"tiles_skipped={rec['tiles_skipped']}")

    baseline = next(r for r in records if r["probe_policy"] == "fixed"
                    and r["nprobe_max"] == nprobe_max)
    wins = [r for r in records if r["probe_policy"] == "margin"
            and r["recall_at_1"] >= baseline["recall_at_1"]
            and r["p99_us"] < baseline["p99_us"]]
    common.emit(
        "serve_frontier_acceptance", 0.0,
        f"adaptive_points_beating_fixed_np{nprobe_max}_baseline={len(wins)} "
        "(acceptance: >= 1 at matched recall@1, strictly lower p99)")
    _merge_frontier(records)
    return records


def main() -> None:
    n_requests = 64 if common.SMOKE else 256
    ds = vectors.make_sift_like(n=common.N_BASE, nt=common.N_TRAIN,
                                nq=max(common.N_QUERY, 128), d=64)
    nlist = max(16, int(math.sqrt(ds.base.shape[0])))
    engine = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                m=8, nlist=nlist, coarse_iters=8, pq_iters=8)
    rng = np.random.default_rng(0)
    queries = np.asarray(ds.queries, np.float32)

    # staged-vs-fused single-dispatch latency at Q=1 (the small-batch regime
    # the fused path exists for), per grouped-scan kernel impl — so the
    # serving numbers separate the kernel win from the dispatch win
    q1 = queries[:1]
    t_fused = None
    for impl in SCAN_IMPLS:
        eng_i = SearchEngine(engine.index, base=engine.base,
                             config=engine.config._replace(scan_impl=impl))
        t_s = common.time_call(
            lambda e=eng_i: e.search(q1, 10, rerank_mult=4).ids, iters=5)
        t_f = common.time_call(
            lambda e=eng_i: e.search_jit(q1, 10, rerank_mult=4).ids, iters=5)
        common.emit(f"serve_fused_speedup_{impl}", t_f,
                    f"staged_us={t_s * 1e6:.1f};"
                    f"speedup={t_s / max(t_f, 1e-12):.2f}x")
        if impl == engine.config.scan_impl:
            t_fused = t_f
    assert t_fused is not None  # SCAN_IMPLS always contains the default impl

    # same decomposition for stage 3: staged vs fused per exact-re-rank impl
    # (the gathered-vs-stream fused delta is the end-to-end cost/benefit of
    # removing the candidate-row gather at serving batch sizes)
    for impl in RERANK_IMPLS:
        eng_i = SearchEngine(engine.index, base=engine.base,
                             config=engine.config._replace(rerank_impl=impl))
        t_s = common.time_call(
            lambda e=eng_i: e.search(q1, 10, rerank_mult=4).ids, iters=5)
        t_f = common.time_call(
            lambda e=eng_i: e.search_jit(q1, 10, rerank_mult=4).ids, iters=5)
        common.emit(f"serve_fused_speedup_rerank_{impl}", t_f,
                    f"staged_us={t_s * 1e6:.1f};"
                    f"speedup={t_s / max(t_f, 1e-12):.2f}x")

    loop = ServingLoop(engine, rerank_mult=4, max_wait_s=0.005)
    loop.start(warmup=True)
    try:
        # calibrate offered loads off the fused dispatch time so the sweep
        # spans under- and over-subscribed regimes on any machine
        base_qps = 1.0 / max(t_fused, 1e-6)
        for label, qps in (("light", 0.25 * base_qps),
                           ("heavy", 2.0 * base_qps)):
            r = _drive(loop, queries, qps, n_requests, rng)
            common.emit(
                f"serve_load_{label}", r["p50_s"],
                f"p99_us={r['p99_s'] * 1e6:.1f};"
                f"qps={r['qps_achieved']:.0f};"
                f"occupancy={r['occupancy']:.2f};"
                f"compiles={r['compiles']}")
    finally:
        loop.stop()

    frontier()


if __name__ == "__main__":
    main()
