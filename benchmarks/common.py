"""Shared benchmark utilities: timing, CSV emission, dataset scaling."""
from __future__ import annotations

import os
import time

import jax

# CPU-scaled defaults; export REPRO_BENCH_FULL=1 for paper-scale (1M vectors)
# or REPRO_BENCH_SMOKE=1 for the CI smoke job (a couple of minutes total).
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
N_BASE = 1_000_000 if FULL else (20_000 if SMOKE else 60_000)
N_TRAIN = 100_000 if FULL else (5_000 if SMOKE else 12_000)
N_QUERY = 1_000 if FULL else (32 if SMOKE else 64)


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time (s) of fn(*args), blocking on the result."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds_per_call: float, derived: str = "") -> None:
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}")
