"""Table 1 reproduction: IVF + HNSW coarse + 4-bit PQ on Deep1B-like data.

Paper: nlist = sqrt(N) (30k for 1B), M=16, K=16, nprobe in {1, 2, 4};
recall@1 and ms/query. We use the same sqrt-N heuristic at our scale and the
same pipeline, now through the unified ``repro.engine.SearchEngine``
(HNSW coarse -> grouped 4-bit fast-scan -> optional exact re-rank), so
recall-vs-latency is measured end-to-end through the production query path.
rerank_mult=0 is the paper's raw quantized pipeline; rerank_mult=4 stacks the
Quicker-ADC-style exact refinement on top.
"""
from __future__ import annotations

import math

import jax

from benchmarks import common
from repro.core import metrics
from repro.data import vectors
from repro.engine import SearchEngine


def main() -> None:
    # finer cluster structure + harder queries than Fig. 2 so that probing
    # more lists matters (matching Table 1's regime: recall rises with
    # nprobe from a low base — the paper reports 0.072 -> 0.086)
    ds = vectors.make_deep_like(n=common.N_BASE, nt=common.N_TRAIN,
                                nq=common.N_QUERY, ncl=4096, query_noise=1.0)
    nlist = max(16, int(math.sqrt(ds.base.shape[0])))
    engine = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                m=16, nlist=nlist, coarse="hnsw",
                                coarse_iters=15, pq_iters=15,
                                hnsw_m=16, ef_construction=64)
    q = ds.queries[:common.N_QUERY]

    for nprobe in (1, 2, 4, 8):
        for rr in (0, 4):
            def pipeline(qq):
                res = engine.search(qq, 10, nprobe=nprobe, rerank_mult=rr)
                return res.dists, res.ids

            t = common.time_call(pipeline, q)
            res = engine.search(q, 10, nprobe=nprobe, rerank_mult=rr)
            r1 = float(metrics.recall_at_r(res.ids, ds.gt_ids, r=1))
            ms_per_query = t / q.shape[0] * 1e3
            scanned = float(res.stats.codes_scanned.mean())
            common.emit(
                f"table1_nlist{nlist}_nprobe{nprobe}_M16_K16_rr{rr}",
                t / q.shape[0],
                f"recall@1={r1:.3f};ms_per_query={ms_per_query:.3f};"
                f"codes_scanned={scanned:.0f}")


if __name__ == "__main__":
    main()
