"""Table 1 reproduction: IVF + HNSW coarse + 4-bit PQ on Deep1B-like data.

Paper: nlist = sqrt(N) (30k for 1B), M=16, K=16, nprobe in {1, 2, 4};
recall@1 and ms/query. We use the same sqrt-N heuristic at our scale and the
same pipeline: HNSW searches the centroids, fast-scan ADC scans the probed
lists (by-residual encoding, u8 LUTs).
"""
from __future__ import annotations

import math

import jax

from benchmarks import common
from repro.core import coarse, ivf, metrics
from repro.data import vectors


def main() -> None:
    # finer cluster structure + harder queries than Fig. 2 so that probing
    # more lists matters (matching Table 1's regime: recall rises with
    # nprobe from a low base — the paper reports 0.072 -> 0.086)
    ds = vectors.make_deep_like(n=common.N_BASE, nt=common.N_TRAIN,
                                nq=common.N_QUERY, ncl=4096, query_noise=1.0)
    nlist = max(16, int(math.sqrt(ds.base.shape[0])))
    index = ivf.build_ivf(jax.random.PRNGKey(0), ds.train, ds.base,
                          m=16, nlist=nlist, coarse_iters=15, pq_iters=15)
    hc = coarse.build_hnsw_coarse(index.centroids, m=16, ef_construction=64)
    q = ds.queries[:common.N_QUERY]

    for nprobe in (1, 2, 4, 8):
        def pipeline(qq):
            _, probes = hc.search(qq, nprobe=nprobe)
            return ivf.search_ivf_precomputed_probes(
                index, qq, probes, nprobe=nprobe, topk=10)

        t = common.time_call(pipeline, q)
        _, ids = pipeline(q)
        r1 = float(metrics.recall_at_r(ids, ds.gt_ids, r=1))
        ms_per_query = t / q.shape[0] * 1e3
        common.emit(f"table1_nlist{nlist}_nprobe{nprobe}_M16_K16",
                    t / q.shape[0],
                    f"recall@1={r1:.3f};ms_per_query={ms_per_query:.3f}")


if __name__ == "__main__":
    main()
