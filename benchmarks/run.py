"""Benchmark harness: one entry per paper table/figure + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV lines (see common.emit). Scaled-down
dataset sizes by default (CPU container); REPRO_BENCH_FULL=1 for paper scale,
REPRO_BENCH_SMOKE=1 for the even smaller CI smoke job.

Exit status: non-zero when any job raised, so CI and scripts can gate on it.
``--out FILE`` tees the CSV to a file (the CI artifact), ``--jobs a,b``
selects a subset.
"""
from __future__ import annotations

import argparse
import contextlib
import pathlib
import sys
import time
import traceback

# allow `python benchmarks/run.py` from anywhere (repo root on sys.path)
_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


class _Tee:
    def __init__(self, *streams):
        self.streams = streams

    def write(self, s):
        for st in self.streams:
            st.write(s)

    def flush(self):
        for st in self.streams:
            st.flush()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="", help="also write the CSV to this file")
    ap.add_argument("--jobs", default="",
                    help="comma-separated job subset (default: all)")
    args = ap.parse_args(argv)

    from benchmarks import (fig2, kernel_bench, mutation_bench, persist_bench,
                            serve_bench, table1)

    jobs = [
        ("kernel_bench", kernel_bench.main),
        ("fig2", fig2.main),
        ("table1", table1.main),
        ("serve_bench", serve_bench.main),
        # after kernel_bench: these append their records into BENCH_kernels.json
        ("mutation_bench", mutation_bench.main),
        ("persist_bench", persist_bench.main),
    ]
    if args.jobs:
        want = {j.strip() for j in args.jobs.split(",") if j.strip()}
        unknown = want - {n for n, _ in jobs}
        if unknown:
            print(f"unknown jobs: {sorted(unknown)}", file=sys.stderr)
            return 2
        jobs = [(n, f) for n, f in jobs if n in want]

    try:
        out_f = open(args.out, "w") if args.out else None
    except OSError as e:
        print(f"cannot open --out file: {e}", file=sys.stderr)
        return 2
    stack = contextlib.ExitStack()
    if out_f is not None:
        stack.enter_context(out_f)
        stack.enter_context(contextlib.redirect_stdout(_Tee(sys.stdout, out_f)))

    failures = []
    with stack:
        print("name,us_per_call,derived")
        for name, fn in jobs:
            t0 = time.time()
            try:
                fn()
            except Exception:
                traceback.print_exc()
                failures.append(name)
            print(f"# {name} finished in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
