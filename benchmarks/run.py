"""Benchmark harness: one entry per paper table/figure + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV lines (see common.emit). Scaled-down
dataset sizes by default (CPU container); REPRO_BENCH_FULL=1 for paper scale.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import fig2, kernel_bench, table1

    print("name,us_per_call,derived")
    jobs = [
        ("kernel_bench", kernel_bench.main),
        ("fig2", fig2.main),
        ("table1", table1.main),
    ]
    failures = []
    for name, fn in jobs:
        t0 = time.time()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} finished in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
