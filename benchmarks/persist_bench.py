"""Durability-path benchmark: snapshot save/load bandwidth + WAL replay rate.

The crash-safe index (docs/persistence.md) trades write-path work for
recovery guarantees; this job puts numbers on both sides so regressions in
the durable path show up next to the kernel sweeps:

  - ``snapshot_save_mb_per_s`` / ``snapshot_load_mb_per_s``: checkpoint
    serialization and CRC-verified deserialization bandwidth over the
    manifest's segment bytes (what the checkpoint thread and a recovering
    boot actually move);
  - ``wal_append_rows_per_s``: upsert throughput WITH the fsync'd WAL
    attached — the delta against mutation_bench's bare
    ``upsert_rows_per_s`` is the price of durability per acknowledged row;
  - ``wal_replay_rows_per_s``: recovery-side replay rate over the same
    records (rows folded back per second through ``open_engine``).

Records append into BENCH_kernels.json (no ``bytes_accessed``, so the
traffic regression check skips them); CSV lines ride ``common.emit``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import persist
from repro.engine import EngineConfig, SearchEngine

KERNELS_JSON = os.environ.get("REPRO_BENCH_KERNELS", "BENCH_kernels.json")

N_BASE = 4_000 if common.SMOKE else 20_000
N_TRAIN = 2_000 if common.SMOKE else 8_000
NLIST = 32 if common.SMOKE else 64
WAL_BATCH = 256
WAL_BATCHES = 4 if common.SMOKE else 8


def _build_engine(d: int = 32, m: int = 8) -> SearchEngine:
    rng = np.random.default_rng(0)
    base = rng.normal(size=(N_BASE, d)).astype(np.float32)
    train = rng.normal(size=(N_TRAIN, d)).astype(np.float32)
    return SearchEngine.build(
        jax.random.PRNGKey(0), jnp.asarray(train), jnp.asarray(base),
        m=m, nlist=NLIST, coarse_iters=4, pq_iters=4,
        config=EngineConfig(nprobe=8, rerank_mult=4))


def _snapshot_bytes(directory: str) -> int:
    manifest = persist.read_manifest(directory)
    total = sum(e["size"] for e in manifest["segments"].values())
    total += sum(sh["size"] for sh in manifest.get("shards", ()))
    return total


def snapshot_bandwidth(eng: SearchEngine, directory: str) -> list[dict]:
    t0 = time.perf_counter()
    persist.save_snapshot(eng, directory)
    t_save = time.perf_counter() - t0
    nbytes = _snapshot_bytes(directory)
    t0 = time.perf_counter()
    persist.load_snapshot(directory)
    t_load = time.perf_counter() - t0
    recs = []
    for metric, t in (("snapshot_save_mb_per_s", t_save),
                      ("snapshot_load_mb_per_s", t_load)):
        mbps = nbytes / t / 1e6
        recs.append({"kernel": "persist", "metric": metric,
                     "snapshot_bytes": nbytes, "mb_per_s": mbps,
                     "backend": jax.default_backend()})
        common.emit(metric.removesuffix("_mb_per_s"), t,
                    f"{mbps:.0f} MB/s over {nbytes / 1e6:.1f} MB of segments")
    return recs


def wal_rates(eng: SearchEngine, directory: str) -> list[dict]:
    """Durable-upsert throughput, then replay rate over the same records."""
    d = int(eng.index.centroids.shape[1])
    rng = np.random.default_rng(1)
    # spare capacity first, so the timed loop isolates encode+append+fsync
    warm = np.arange(N_BASE, N_BASE + WAL_BATCH)
    eng.upsert(warm, rng.normal(size=(WAL_BATCH, d)).astype(np.float32))
    persist.save_snapshot(eng, directory)  # replay below starts here
    t0 = time.perf_counter()
    for b in range(WAL_BATCHES):
        ids = np.arange(N_BASE + (b + 1) * WAL_BATCH,
                        N_BASE + (b + 2) * WAL_BATCH)
        eng.upsert(ids, rng.normal(size=(WAL_BATCH, d)).astype(np.float32))
    dt_append = time.perf_counter() - t0
    rows = WAL_BATCH * WAL_BATCHES
    t0 = time.perf_counter()
    _rec, info = persist.open_engine(directory, attach=False)
    dt_replay = time.perf_counter() - t0
    assert info.replayed == WAL_BATCHES
    recs = [
        {"kernel": "persist", "metric": "wal_append_rows_per_s",
         "batch": WAL_BATCH, "batches": WAL_BATCHES,
         "rows_per_s": rows / dt_append, "backend": jax.default_backend()},
        {"kernel": "persist", "metric": "wal_replay_rows_per_s",
         "batch": WAL_BATCH, "batches": WAL_BATCHES,
         "rows_per_s": rows / dt_replay, "backend": jax.default_backend()},
    ]
    common.emit("persist_wal_append_batch", dt_append / WAL_BATCHES,
                f"{rows / dt_append:.0f} rows/s through fsync'd durable "
                f"upsert (batch={WAL_BATCH})")
    common.emit("persist_wal_replay", dt_replay,
                f"{rows / dt_replay:.0f} rows/s replayed through "
                f"open_engine ({WAL_BATCHES} records)")
    return recs


def _merge_records(new: list[dict]) -> None:
    """Append into BENCH_kernels.json without clobbering earlier jobs."""
    try:
        with open(KERNELS_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = {"schema": "repro.kernel_bench/v1", "records": []}
    kept = [r for r in data.get("records", [])
            if r.get("kernel") != "persist"]
    data["records"] = kept + new
    with open(KERNELS_JSON, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def main() -> None:
    eng = _build_engine()
    tmp = tempfile.mkdtemp(prefix="persist_bench_")
    try:
        snap_recs = snapshot_bandwidth(eng, os.path.join(tmp, "snap"))
        wal_dir = os.path.join(tmp, "wal")
        persist.ensure_attached(eng, wal_dir)
        wal_recs = wal_rates(eng, wal_dir)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    _merge_records(snap_recs + wal_recs)
    print(f"# persist_bench: appended {len(snap_recs) + len(wal_recs)} "
          f"records to {KERNELS_JSON}")


if __name__ == "__main__":
    main()
