"""Durability-path benchmark: snapshot save/load bandwidth + WAL replay rate.

The crash-safe index (docs/persistence.md) trades write-path work for
recovery guarantees; this job puts numbers on both sides so regressions in
the durable path show up next to the kernel sweeps:

  - ``snapshot_save_mb_per_s`` / ``snapshot_load_mb_per_s``: checkpoint
    serialization and CRC-verified deserialization bandwidth over the
    manifest's segment bytes (what the checkpoint thread and a recovering
    boot actually move);
  - ``wal_append_rows_per_s``: upsert throughput WITH the fsync'd WAL
    attached — the delta against mutation_bench's bare
    ``upsert_rows_per_s`` is the price of durability per acknowledged row;
  - ``wal_replay_rows_per_s``: recovery-side replay rate over the same
    records (rows folded back per second through ``open_engine``);
  - ``checkpoint_bytes`` full vs delta: bytes a checkpoint physically
    writes when every segment is new versus when content-hash dedup
    reuses the unchanged ones from the parent manifest — the write_ratio
    is what the traffic watcher trends;
  - ``replication_lag``: one ship/poll round-trip through a
    ``DirTransport`` — seqs behind before the poll, seqs + seconds after
    (after must be zero: a caught-up standby), and the replay rate.

Records append into BENCH_kernels.json (no ``bytes_accessed``, so the
scan-traffic diff skips them; ``check_bench_traffic.py`` watches the
checkpoint write_ratio and replication lag separately, non-blocking).
CSV lines ride ``common.emit``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import persist
from repro.engine import EngineConfig, SearchEngine

KERNELS_JSON = os.environ.get("REPRO_BENCH_KERNELS", "BENCH_kernels.json")

N_BASE = 4_000 if common.SMOKE else 20_000
N_TRAIN = 2_000 if common.SMOKE else 8_000
NLIST = 32 if common.SMOKE else 64
WAL_BATCH = 256
WAL_BATCHES = 4 if common.SMOKE else 8


def _build_engine(d: int = 32, m: int = 8) -> SearchEngine:
    rng = np.random.default_rng(0)
    base = rng.normal(size=(N_BASE, d)).astype(np.float32)
    train = rng.normal(size=(N_TRAIN, d)).astype(np.float32)
    return SearchEngine.build(
        jax.random.PRNGKey(0), jnp.asarray(train), jnp.asarray(base),
        m=m, nlist=NLIST, coarse_iters=4, pq_iters=4,
        config=EngineConfig(nprobe=8, rerank_mult=4))


def _snapshot_bytes(directory: str) -> int:
    manifest = persist.read_manifest(directory)
    total = sum(e["size"] for e in manifest["segments"].values())
    total += sum(sh["size"] for sh in manifest.get("shards", ()))
    return total


def snapshot_bandwidth(eng: SearchEngine, directory: str) -> list[dict]:
    t0 = time.perf_counter()
    persist.save_snapshot(eng, directory)
    t_save = time.perf_counter() - t0
    nbytes = _snapshot_bytes(directory)
    t0 = time.perf_counter()
    persist.load_snapshot(directory)
    t_load = time.perf_counter() - t0
    recs = []
    for metric, t in (("snapshot_save_mb_per_s", t_save),
                      ("snapshot_load_mb_per_s", t_load)):
        mbps = nbytes / t / 1e6
        recs.append({"kernel": "persist", "metric": metric,
                     "snapshot_bytes": nbytes, "mb_per_s": mbps,
                     "backend": jax.default_backend()})
        common.emit(metric.removesuffix("_mb_per_s"), t,
                    f"{mbps:.0f} MB/s over {nbytes / 1e6:.1f} MB of segments")
    return recs


def wal_rates(eng: SearchEngine, directory: str) -> list[dict]:
    """Durable-upsert throughput, then replay rate over the same records."""
    d = int(eng.index.centroids.shape[1])
    rng = np.random.default_rng(1)
    # spare capacity first, so the timed loop isolates encode+append+fsync
    warm = np.arange(N_BASE, N_BASE + WAL_BATCH)
    eng.upsert(warm, rng.normal(size=(WAL_BATCH, d)).astype(np.float32))
    persist.save_snapshot(eng, directory)  # replay below starts here
    t0 = time.perf_counter()
    for b in range(WAL_BATCHES):
        ids = np.arange(N_BASE + (b + 1) * WAL_BATCH,
                        N_BASE + (b + 2) * WAL_BATCH)
        eng.upsert(ids, rng.normal(size=(WAL_BATCH, d)).astype(np.float32))
    dt_append = time.perf_counter() - t0
    rows = WAL_BATCH * WAL_BATCHES
    t0 = time.perf_counter()
    _rec, info = persist.open_engine(directory, attach=False)
    dt_replay = time.perf_counter() - t0
    assert info.replayed == WAL_BATCHES
    recs = [
        {"kernel": "persist", "metric": "wal_append_rows_per_s",
         "batch": WAL_BATCH, "batches": WAL_BATCHES,
         "rows_per_s": rows / dt_append, "backend": jax.default_backend()},
        {"kernel": "persist", "metric": "wal_replay_rows_per_s",
         "batch": WAL_BATCH, "batches": WAL_BATCHES,
         "rows_per_s": rows / dt_replay, "backend": jax.default_backend()},
    ]
    common.emit("persist_wal_append_batch", dt_append / WAL_BATCHES,
                f"{rows / dt_append:.0f} rows/s through fsync'd durable "
                f"upsert (batch={WAL_BATCH})")
    common.emit("persist_wal_replay", dt_replay,
                f"{rows / dt_replay:.0f} rows/s replayed through "
                f"open_engine ({WAL_BATCHES} records)")
    return recs


def checkpoint_delta(eng: SearchEngine, directory: str) -> list[dict]:
    """Bytes a checkpoint writes: full (no parent) vs delta (parent dedup).

    ``snapshot_bandwidth`` already left a full snapshot in ``directory``;
    touch a sliver of the index and checkpoint again — codes/ids/sizes
    are rewritten but centroids/codebook/base CRC-match the parent and
    are referenced, not copied.
    """
    gids = np.asarray(eng.index.lists.ids)
    sel = np.sort(gids[gids >= 0])[:64]
    eng.delete(sel)
    t0 = time.perf_counter()
    manifest = persist.save_snapshot(eng, directory)
    dt = time.perf_counter() - t0
    delta = manifest["delta"]
    total = delta["bytes_written"] + delta["bytes_reused"]
    ratio = delta["bytes_written"] / total if total else 1.0
    recs = [
        {"kernel": "persist", "metric": "checkpoint_bytes",
         "mode": "full", "bytes_written": total, "bytes_reused": 0,
         "write_ratio": 1.0, "backend": jax.default_backend()},
        {"kernel": "persist", "metric": "checkpoint_bytes",
         "mode": "delta", "bytes_written": delta["bytes_written"],
         "bytes_reused": delta["bytes_reused"], "write_ratio": ratio,
         "segments_written": delta["segments_written"],
         "segments_reused": delta["segments_reused"],
         "backend": jax.default_backend()},
    ]
    common.emit("persist_checkpoint_delta", dt,
                f"delta checkpoint wrote {delta['bytes_written'] / 1e6:.2f} "
                f"MB, reused {delta['bytes_reused'] / 1e6:.1f} MB "
                f"(write_ratio {ratio:.3f})")
    return recs


def replication_rates(eng: SearchEngine, wal_dir: str,
                      ship_dir: str) -> list[dict]:
    """One ship/poll round-trip: lag before the poll, lag after, replay
    rate. The standby starts from the primary's own snapshot (bit-exact
    warm start), so only the freshly shipped records cross the wire."""
    d = int(eng.index.centroids.shape[1])
    rng = np.random.default_rng(3)
    transport = persist.DirTransport(ship_dir)
    shipper = persist.WALShipper(eng, wal_dir, transport)
    shipper.ship_once()  # backlog out of the way before the timed round
    standby, info = persist.open_engine(wal_dir, attach=False)
    replica = persist.StandbyReplica(standby, transport,
                                     start_seq=info.last_seq)
    replica.poll_once()
    rows = WAL_BATCH * WAL_BATCHES
    base_id = 10 * N_BASE
    for b in range(WAL_BATCHES):
        ids = np.arange(base_id + b * WAL_BATCH,
                        base_id + (b + 1) * WAL_BATCH)
        eng.upsert(ids, rng.normal(size=(WAL_BATCH, d)).astype(np.float32))
    t0 = time.perf_counter()
    shipper.ship_once()
    dt_ship = time.perf_counter() - t0
    lag_before = replica.lag()
    t0 = time.perf_counter()
    replica.poll_once()
    dt_replay = time.perf_counter() - t0
    lag_after = replica.lag()
    rec = {"kernel": "persist", "metric": "replication_lag",
           "batch": WAL_BATCH, "batches": WAL_BATCHES,
           "lag_seqs_before_poll": lag_before.seqs,
           "lag_seqs": lag_after.seqs, "lag_s": lag_after.seconds,
           "ship_s": dt_ship, "replay_rows_per_s": rows / dt_replay,
           "backend": jax.default_backend()}
    common.emit("persist_replication_roundtrip", dt_ship + dt_replay,
                f"shipped+replayed {rows} rows ({rows / dt_replay:.0f} "
                f"rows/s replay), lag {lag_before.seqs}->{lag_after.seqs} "
                "seqs")
    return [rec]


def _merge_records(new: list[dict]) -> None:
    """Append into BENCH_kernels.json without clobbering earlier jobs."""
    try:
        with open(KERNELS_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = {"schema": "repro.kernel_bench/v1", "records": []}
    kept = [r for r in data.get("records", [])
            if r.get("kernel") != "persist"]
    data["records"] = kept + new
    with open(KERNELS_JSON, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def main() -> None:
    eng = _build_engine()
    tmp = tempfile.mkdtemp(prefix="persist_bench_")
    try:
        snap_recs = snapshot_bandwidth(eng, os.path.join(tmp, "snap"))
        delta_recs = checkpoint_delta(eng, os.path.join(tmp, "snap"))
        wal_dir = os.path.join(tmp, "wal")
        persist.ensure_attached(eng, wal_dir)
        wal_recs = wal_rates(eng, wal_dir)
        repl_recs = replication_rates(eng, wal_dir, os.path.join(tmp, "ship"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    recs = snap_recs + delta_recs + wal_recs + repl_recs
    _merge_records(recs)
    print(f"# persist_bench: appended {len(recs)} records to {KERNELS_JSON}")


if __name__ == "__main__":
    main()
