"""Mutation-path benchmark: upsert throughput + tombstone search overhead.

The live mutable index (docs/mutability.md) promises two things worth
tracking as numbers: writes are cheap (PQ-encode + scatter into spare
slots, no rebuild), and reads degrade gracefully under tombstone load (the
live-row bitmap rides the same masked pre-selection as the user filter, so
a tombstoned row costs a masked lane, never a rebuild or a post-filter
pass). This job records:

  - ``upsert_rows_per_s``: steady-state rows/second through
    ``SearchEngine.upsert`` at a fixed batch size, spare capacity
    pre-grown so the number isolates the append path (no compaction, no
    cap growth mid-measurement);
  - ``search_us`` at 0% / 10% / 50% tombstone load, same engine, same
    queries — the deltas are the read-side cost of deferring compaction;
  - ``upsert_rows_per_s_durable``: the same append path with the WAL
    attached, once fsyncing every record and once under group commit
    (``WALWriter(fsync_interval=...)``) — the two deltas against the bare
    number are the price of the ack and how much group commit buys back.

Records append into BENCH_kernels.json next to the kernel sweeps (they
carry no ``bytes_accessed``, so the traffic regression check skips them);
the CSV lines ride the normal ``common.emit`` stream.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import persist
from repro.core.lists import live_counts
from repro.engine import EngineConfig, SearchEngine
from repro.persist.wal import WALWriter, wal_name

KERNELS_JSON = os.environ.get("REPRO_BENCH_KERNELS", "BENCH_kernels.json")

N_BASE = 4_000 if common.SMOKE else 20_000
N_TRAIN = 2_000 if common.SMOKE else 8_000
NLIST = 32 if common.SMOKE else 64
UPSERT_BATCH = 256
UPSERT_BATCHES = 4 if common.SMOKE else 8


def _build_engine(d: int = 32, m: int = 8) -> tuple[SearchEngine, np.ndarray]:
    rng = np.random.default_rng(0)
    base = rng.normal(size=(N_BASE, d)).astype(np.float32)
    train = rng.normal(size=(N_TRAIN, d)).astype(np.float32)
    eng = SearchEngine.build(
        jax.random.PRNGKey(0), jnp.asarray(train), jnp.asarray(base),
        m=m, nlist=NLIST, coarse_iters=4, pq_iters=4,
        config=EngineConfig(nprobe=8, rerank_mult=4))
    q = rng.normal(size=(32, d)).astype(np.float32)
    return eng, q


def upsert_throughput(eng: SearchEngine) -> tuple[float, dict]:
    """Rows/second through the append path at UPSERT_BATCH granularity."""
    d = int(eng.index.centroids.shape[1])
    rng = np.random.default_rng(1)
    # pre-grow spare capacity once so the timed loop never compacts or
    # reallocates — that's the steady-state serving write path
    total = UPSERT_BATCH * (UPSERT_BATCHES + 1)
    warm_ids = np.arange(N_BASE, N_BASE + UPSERT_BATCH)
    eng.upsert(warm_ids, rng.normal(size=(UPSERT_BATCH, d)).astype(np.float32))
    t0 = time.perf_counter()
    for b in range(UPSERT_BATCHES):
        ids = np.arange(N_BASE + (b + 1) * UPSERT_BATCH,
                        N_BASE + (b + 2) * UPSERT_BATCH)
        eng.upsert(ids, rng.normal(size=(UPSERT_BATCH, d)).astype(np.float32))
    dt = time.perf_counter() - t0
    rows_per_s = UPSERT_BATCH * UPSERT_BATCHES / dt
    rec = {"kernel": "mutation", "metric": "upsert_rows_per_s",
           "batch": UPSERT_BATCH, "batches": UPSERT_BATCHES,
           "rows_per_s": rows_per_s, "backend": jax.default_backend()}
    common.emit("mutation_upsert_batch", dt / UPSERT_BATCHES,
                f"{rows_per_s:.0f} rows/s through upsert "
                f"(batch={UPSERT_BATCH}, total={total} rows)")
    return rows_per_s, rec


def tombstone_latency(eng: SearchEngine, q: np.ndarray) -> list[dict]:
    """search_jit latency at 0%/10%/50% tombstone load on one engine."""
    qj = jnp.asarray(q)
    n_live0 = int(np.asarray(live_counts(eng.index.lists)).sum())
    gids = np.asarray(eng.index.lists.ids)
    gids = np.sort(gids[gids >= 0])
    records = []
    t_base = None
    deleted = 0
    for load in (0.0, 0.10, 0.50):
        want_dead = int(round(n_live0 * load))
        if want_dead > deleted:
            # spread deletions uniformly over the id space so every probed
            # list carries its share of tombstones
            sel = gids[np.linspace(0, gids.size - 1, want_dead,
                                   dtype=np.int64)]
            already = deleted
            eng.delete(sel)
            deleted = n_live0 - int(np.asarray(
                live_counts(eng.index.lists)).sum())
            assert deleted >= already
        t = common.time_call(lambda: eng.search_jit(qj, 10))
        if t_base is None:
            t_base = t
        delta = (t / t_base - 1.0) * 100.0
        records.append({
            "kernel": "mutation", "metric": "search_us",
            "tombstone_load": load, "Q": int(q.shape[0]),
            "us_per_call": t * 1e6, "delta_vs_clean_pct": delta,
            "backend": jax.default_backend()})
        common.emit(f"mutation_search_tomb{int(load * 100)}", t,
                    f"search_jit at {int(load * 100)}% tombstones "
                    f"({delta:+.1f}% vs clean)")
    return records


def durable_upsert_delta(eng: SearchEngine,
                         bare_rows_per_s: float) -> list[dict]:
    """Durable upsert rows/s: fsync-per-record vs group commit.

    Runs on the engine ``tombstone_latency`` left behind — the append
    path doesn't care about tombstone load, and reusing it skips a
    second expensive build.
    """
    d = int(eng.index.centroids.shape[1])
    rng = np.random.default_rng(4)
    tmp = tempfile.mkdtemp(prefix="mutation_bench_wal_")
    next_id = 100 * N_BASE

    def timed_run() -> float:
        nonlocal next_id
        # one warm batch so cap growth/compaction never lands in the loop
        eng.upsert(np.arange(next_id, next_id + UPSERT_BATCH),
                   rng.normal(size=(UPSERT_BATCH, d)).astype(np.float32))
        next_id += UPSERT_BATCH
        t0 = time.perf_counter()
        for _ in range(UPSERT_BATCHES):
            eng.upsert(np.arange(next_id, next_id + UPSERT_BATCH),
                       rng.normal(size=(UPSERT_BATCH, d)).astype(np.float32))
            next_id += UPSERT_BATCH
        dt = time.perf_counter() - t0
        return UPSERT_BATCH * UPSERT_BATCHES / dt

    recs = []
    try:
        persist.ensure_attached(eng, tmp)  # default: fsync every record
        r_each = timed_run()
        old = eng._wal
        old.close()
        seq = old.last_seq + 1
        eng.attach_wal(WALWriter(os.path.join(tmp, wal_name(seq)), seq,
                                 fsync_interval=0.05))
        r_group = timed_run()
        eng._wal.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    for mode, rows_per_s in (("each", r_each), ("group", r_group)):
        delta = (rows_per_s / bare_rows_per_s - 1.0) * 100.0
        rec = {"kernel": "mutation", "metric": "upsert_rows_per_s_durable",
               "fsync": mode, "batch": UPSERT_BATCH,
               "batches": UPSERT_BATCHES, "rows_per_s": rows_per_s,
               "delta_vs_bare_pct": delta,
               "backend": jax.default_backend()}
        if mode == "group":
            rec["fsync_interval_s"] = 0.05
        recs.append(rec)
        common.emit(f"mutation_upsert_durable_{mode}",
                    UPSERT_BATCH / rows_per_s,
                    f"{rows_per_s:.0f} rows/s durable upsert "
                    f"(fsync={mode}, {delta:+.1f}% vs bare)")
    return recs


def _merge_records(new: list[dict]) -> None:
    """Append into BENCH_kernels.json without clobbering the kernel sweeps
    (kernel_bench.main overwrites the file; this job runs after it)."""
    try:
        with open(KERNELS_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = {"schema": "repro.kernel_bench/v1", "records": []}
    kept = [r for r in data.get("records", [])
            if r.get("kernel") != "mutation"]
    data["records"] = kept + new
    with open(KERNELS_JSON, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def main() -> None:
    eng, q = _build_engine()
    bare_rows_per_s, up_rec = upsert_throughput(eng)
    lat_recs = tombstone_latency(eng, q)
    durable_recs = durable_upsert_delta(eng, bare_rows_per_s)
    recs = [up_rec] + lat_recs + durable_recs
    _merge_records(recs)
    print(f"# mutation_bench: appended {len(recs)} records to "
          f"{KERNELS_JSON}")


if __name__ == "__main__":
    main()
