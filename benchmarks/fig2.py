"""Fig. 2 reproduction: 4-bit fast-scan PQ vs original PQ, SIFT1M/Deep1M-like.

The paper's claim has two parts:
  (1) ACCURACY PARITY: at equal M (K=16 both), fast-scan's u8-quantized LUT
      loses no recall vs the float-LUT scan — we measure recall@{1,10} for
      both pipelines on both datasets.
  (2) 10x SPEEDUP: in-register shuffle vs memory gather. Wall-clock on this
      CPU container reflects the interpreter, not TPU silicon, so we report
      measured time AND the roofline-model speedup for the TPU kernels
      (bytes-per-code analysis; see kernel_bench.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import fastscan, metrics, pq
from repro.data import vectors


def run_dataset(tag: str, ds, ms=(8, 16, 32)) -> None:
    key = jax.random.PRNGKey(0)
    q = ds.queries[:common.N_QUERY]
    for m in ms:
        idx = fastscan.build_index(key, ds.train, ds.base, m=m, iters=15)
        codes_naive = pq.encode(idx.codebook, ds.base)

        naive = jax.jit(functools.partial(pq.search, topk=10))
        fast = jax.jit(functools.partial(fastscan.search, topk=10, impl="mxu"))

        t_naive = common.time_call(naive, idx.codebook, codes_naive, q)
        t_fast = common.time_call(fast, idx, q)
        _, ids_naive = naive(idx.codebook, codes_naive, q)
        _, ids_fast = fast(idx, q)
        r1n = float(metrics.recall_at_r(ids_naive, ds.gt_ids, r=1))
        r1f = float(metrics.recall_at_r(ids_fast, ds.gt_ids, r=1))
        r10n = float(metrics.recall_at_r(ids_naive, ds.gt_ids, r=10))
        r10f = float(metrics.recall_at_r(ids_fast, ds.gt_ids, r=10))
        nq = q.shape[0]
        common.emit(
            f"fig2_{tag}_M{m}_naivePQ", t_naive / nq,
            f"recall@1={r1n:.3f};recall@10={r10n:.3f}")
        common.emit(
            f"fig2_{tag}_M{m}_fastscan", t_fast / nq,
            f"recall@1={r1f:.3f};recall@10={r10f:.3f};"
            f"parity_gap_r10={abs(r10f - r10n):.3f}")


def main() -> None:
    ds_sift = vectors.make_sift_like(n=common.N_BASE, nt=common.N_TRAIN,
                                     nq=common.N_QUERY)
    run_dataset("sift1m", ds_sift)
    ds_deep = vectors.make_deep_like(n=common.N_BASE, nt=common.N_TRAIN,
                                     nq=common.N_QUERY)
    run_dataset("deep1m", ds_deep)


if __name__ == "__main__":
    main()
