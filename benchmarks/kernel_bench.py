"""Kernel-level benchmark: register-resident LUT vs memory LUT (paper §3).

Wall-clock on this container reflects the Pallas *interpreter* on CPU, so we
report it only as a correctness-path cost. The TPU claim is made with the
roofline model: bytes-per-code of each formulation at the VMEM/HBM boundary,
which is the structural content of the paper's 10x (in-register shuffle
eliminates the per-code random LUT load).

  naive PQ (K=256, u8 codes, f32 LUT in HBM/L2): per code-subspace lookup
    reads 1 code byte + one 4 B random table entry -> gather-bound.
  4-bit fast-scan (K=16, u8 LUT in VMEM/registers): per code-subspace 0.5
    byte of codes streams through; the LUT never leaves the register file.
"""
from __future__ import annotations

import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import ivf
from repro.core.lists import ListStore, base_norms, pack_filter_mask
from repro.core.pq import PQCodebook
from repro.engine import rerank as rerank_mod
from repro.kernels import ops, ref
from repro.launch import roofline as rl
from repro.launch.hlo_analysis import xla_cost_dict

# machine-readable grouped-kernel sweep artifact (CI uploads it; the perf
# trajectory across PRs reads it). Override the path with REPRO_BENCH_KERNELS.
KERNELS_JSON = os.environ.get("REPRO_BENCH_KERNELS", "BENCH_kernels.json")


def roofline_model(m: int = 16, n: int = 10**6, q: int = 1) -> dict:
    """Analytic time-per-query on a v5e chip for both formulations."""
    # naive PQ: N*M random gathers of 4 B each (table too big for registers;
    # scalar pipeline ~1 lookup/cycle/core analogue: we charge HBM latency-
    # amortized random access at cacheline granularity / 8 useful bytes)
    naive_bytes = n * m * (1 + 4)          # code byte + table entry
    # fast-scan: codes stream 0.5 B/subspace; LUT resident; accum in-reg
    fast_bytes = n * m * 0.5
    # MXU formulation: onehot(codes) @ LUT = N * (M*16) * Q MACs
    mxu_flops = 2 * n * m * 16 * q
    return {
        "naive_t": naive_bytes / rl.HBM_BW,
        "fast_t": max(fast_bytes / rl.HBM_BW, mxu_flops / rl.PEAK_FLOPS / 8),
        "mxu_t": max(fast_bytes / rl.HBM_BW, mxu_flops / rl.PEAK_FLOPS),
    }


def grouped_sweep(m: int = 16) -> list[dict]:
    """Time every grouped impl (incl. the autotuned dispatch) over (G, cap)
    points of the IVF hot path: G = Q*nprobe gathered lists of capacity cap.

    Returns one record per (shape, impl) for BENCH_kernels.json; each record
    carries cost_analysis ``bytes_accessed`` alongside wall time so the perf
    trajectory tracks HBM traffic, not just clock.
    """
    rng = np.random.default_rng(0)
    points = ([(8, 128), (32, 256), (8, 1024)] if common.SMOKE else
              [(8, 256), (64, 256), (8, 1024), (256, 512)])
    records = []
    for g, cap in points:
        table = jnp.asarray(rng.integers(0, 256, (g, m, 16), np.uint8))
        codes = jnp.asarray(rng.integers(0, 256, (g, cap, m // 2), np.uint8))
        for impl in ops.SCAN_IMPLS:  # ref / select / mxu / stream / auto
            t = common.time_call(ops.fastscan_grouped, table, codes, impl=impl)
            cost = xla_cost_dict(jax.jit(functools.partial(
                ops.fastscan_grouped, impl=impl)).lower(table, codes).compile())
            rec = {"kernel": "fastscan_grouped", "impl": impl, "G": g,
                   "cap": cap, "M": m, "us_per_call": t * 1e6,
                   "bytes_accessed": cost.get("bytes accessed", 0.0),
                   "backend": jax.default_backend()}
            if impl == "auto":
                tuned = ops.resolve_grouped_impl(g, cap, m)
                rec["resolved"] = {"impl": tuned.impl, "tile_n": tuned.tile_n}
            records.append(rec)
            common.emit(f"kernel_grouped_{impl}_G{g}_cap{cap}_M{m}", t,
                        "grouped IVF-hot-path scan (interpret mode off-TPU)")
    return records


def scan_stage_traffic(q: int = 32, p: int = 16, cap: int = 1024,
                       m: int = 16, nlist: int = 64) -> list[dict]:
    """HBM bytes-accessed of the whole scan STAGE, gathered vs gather-free.

    The gathered path is ``core.ivf.scan_probes(impl='ref')``: gather the
    probed lists, scan, write the full (Q, P, cap) distances + ids back.
    The streamed path is ``scan_probes_stream``: in-kernel list DMA + fused
    per-tile reduction — only (Q, P, n_tiles, kc) candidates return to HBM.
    Both are *compiled only* (cost_analysis needs no execution), so this
    runs at the acceptance shape (Q=32, P=16, cap=1024, M=16) even in the
    CI smoke job. ``keep=40`` is the serving default's selection budget
    (rerank_mult=4 x k=10).
    """
    rng = np.random.default_rng(0)
    d = 32
    codes = rng.integers(0, 256, (nlist, cap, m // 2), np.uint8)
    ids = np.arange(nlist * cap, dtype=np.int32).reshape(nlist, cap)
    index = ivf.IVFIndex(
        centroids=jnp.asarray(rng.normal(size=(nlist, d)).astype(np.float32)),
        codebook=PQCodebook(jnp.asarray(
            rng.normal(size=(m, 16, d // m)).astype(np.float32))),
        lists=ListStore(codes=jnp.asarray(codes), ids=jnp.asarray(ids),
                        sizes=jnp.asarray(np.full(nlist, cap, np.int32))),
    )
    qs = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    probes = jnp.asarray(rng.integers(0, nlist, (q, p)).astype(np.int32))
    # all-ones (100% selectivity) filter bitmap: measures the pure bitmap
    # overhead of the filtered stream scan — docs/filtering.md promises it
    # within 10% of the unfiltered stream record
    fbits = pack_filter_mask(index.lists.ids >= 0)
    stages = (
        ("gathered", jax.jit(
            lambda i, qq, pr: ivf.scan_probes(i, qq, pr, impl="ref")),
         (index, qs, probes)),
        ("stream", jax.jit(functools.partial(ivf.scan_probes_stream,
                                             keep=40)),
         (index, qs, probes)),
        ("stream_filtered", jax.jit(
            lambda i, qq, pr, fb: ivf.scan_probes_stream(
                i, qq, pr, keep=40, filter_bits=fb)),
         (index, qs, probes, fbits)),
    )
    records = []
    for name, fn, args in stages:
        cost = xla_cost_dict(fn.lower(*args).compile())
        rec = {"kernel": "scan_stage", "impl": name, "Q": q, "P": p,
               "cap": cap, "M": m, "nlist": nlist,
               "bytes_accessed": cost.get("bytes accessed", 0.0),
               "backend": jax.default_backend()}
        records.append(rec)
        common.emit(f"scan_stage_bytes_{name}", 0.0,
                    f"bytes_accessed={rec['bytes_accessed']:.0f}")
    if records[1]["bytes_accessed"]:
        ratio = records[0]["bytes_accessed"] / records[1]["bytes_accessed"]
        common.emit("scan_stage_traffic_ratio", 0.0,
                    f"gathered/stream={ratio:.1f}x (acceptance: >= 4x)")
        overhead = (records[2]["bytes_accessed"]
                    / records[1]["bytes_accessed"] - 1.0)
        common.emit("scan_stage_filter_overhead", 0.0,
                    f"filtered/unfiltered-1={overhead:+.1%} "
                    "(acceptance: within 10%)")
    return records


def anytime_scan_traffic(q: int = 32, p: int = 16, cap: int = 1024,
                         m: int = 16, nlist: int = 64, tile: int = 256,
                         tau: float = 2.0) -> list[dict]:
    """Scan-stage traffic under the anytime policy on a margin-skewed mix.

    XLA's static cost model cannot see data-dependent work, so this record
    models the stream scan's dominant HBM term directly from the kernel's
    own counters: codes-DMA bytes = tiles actually scanned x ``tile x M/2``
    plus one (M, 16) LUT per valid group. The "margin-skewed mix" is the
    regime docs/anytime.md targets — clustered data, every query near one
    centroid — so the coarse margins are real: the margin policy drops
    whole probes (their groups' DMAs never issue) and the early-exit bound
    skips surviving far groups' tiles in-kernel. Recall is matched by
    construction *and checked*: the adaptive pool's final top-10 against
    the fixed-nprobe pool's (acceptance: recall@10 >= 0.99 with >= 25%
    fewer modeled bytes).
    """
    from repro.core.topk import (gather_ids, margin_prune_probes, masked_topk,
                                 smallest_k)

    rng = np.random.default_rng(0)
    d = 32
    # well-separated centroids + near-centroid queries = real coarse margins
    centroids = rng.normal(size=(nlist, d)).astype(np.float32) * 4.0
    codes = rng.integers(0, 256, (nlist, cap, m // 2), np.uint8)
    ids = np.arange(nlist * cap, dtype=np.int32).reshape(nlist, cap)
    index = ivf.IVFIndex(
        centroids=jnp.asarray(centroids),
        codebook=PQCodebook(jnp.asarray(
            rng.normal(size=(m, 16, d // m)).astype(np.float32))),
        lists=ListStore(codes=jnp.asarray(codes), ids=jnp.asarray(ids),
                        sizes=jnp.asarray(np.full(nlist, cap, np.int32))),
    )
    # half the queries sit on a centroid (tight margin: the policy prunes
    # all but the home probe), half sit between two clusters (wide margin:
    # both survive the prune and the early-exit bound skips the farther
    # group's tiles in-kernel) — both anytime mechanisms show up in the
    # counters below
    home = rng.integers(0, nlist, q)
    mate = (home + 1) % nlist
    w = np.where(np.arange(q) < q // 2, 0.0, 0.42).astype(np.float32)[:, None]
    qs = jnp.asarray((1.0 - w) * centroids[home] + w * centroids[mate]
                     + 0.3 * rng.normal(size=(q, d)).astype(np.float32))
    cd = jnp.sum((qs[:, None, :] - index.centroids[None]) ** 2, axis=-1)
    cvals, probes = smallest_k(cd, p)
    adp_probes, lists_pruned = margin_prune_probes(cvals, probes, tau)

    keep = 40
    fix_d, fix_i = ivf.scan_probes_stream(index, qs, probes, keep=keep,
                                          tile_n=tile)
    adp_d, adp_i, skipped = ivf.scan_probes_stream(index, qs, adp_probes,
                                                   keep=keep, tile_n=tile,
                                                   early_exit=True)

    def _final10(dd, ii):
        v, pos = masked_topk(dd, ii >= 0, 10)
        return np.asarray(gather_ids(ii, pos))

    want, got = _final10(fix_d, fix_i), _final10(adp_d, adp_i)
    recall10 = float(np.mean([np.isin(got[i], want[i]).mean()
                              for i in range(q)]))

    n_tiles = cap // tile
    group_lut = m * 16                      # (M, 16) u8 LUT per valid group
    tile_bytes = tile * (m // 2)            # packed-codes DMA per tile
    pruned = int(np.asarray(lists_pruned).sum())
    n_skip = int(np.asarray(skipped).sum())
    fixed_bytes = q * p * (n_tiles * tile_bytes + group_lut)
    valid_groups = q * p - pruned
    adp_bytes = (valid_groups * group_lut
                 + (valid_groups * n_tiles - n_skip) * tile_bytes)
    reduction = 1.0 - adp_bytes / fixed_bytes
    base = {"kernel": "anytime_scan", "Q": q, "P": p, "cap": cap, "M": m,
            "nlist": nlist, "tile_n": tile, "modeled": True,
            "backend": jax.default_backend()}
    records = [
        dict(base, impl="fixed", bytes_accessed=float(fixed_bytes)),
        dict(base, impl="adaptive", bytes_accessed=float(adp_bytes),
             margin_tau=tau, lists_pruned=pruned, tiles_skipped=n_skip,
             reduction_pct=reduction * 100.0, recall10_vs_fixed=recall10),
    ]
    common.emit("anytime_scan_bytes_fixed", 0.0,
                f"modeled_bytes={fixed_bytes}")
    common.emit("anytime_scan_bytes_adaptive", 0.0,
                f"modeled_bytes={adp_bytes};lists_pruned={pruned};"
                f"tiles_skipped={n_skip};reduction={reduction:.1%};"
                f"recall10_vs_fixed={recall10:.3f} "
                "(acceptance: >= 25% fewer bytes at matched recall)")
    assert reduction >= 0.25, f"anytime reduction {reduction:.1%} < 25%"
    assert recall10 >= 0.99, f"anytime recall@10 {recall10:.3f} < 0.99"
    return records


def rerank_stage_traffic(q: int = 32, k: int = 10, r: int = 4,
                         d: int = 128, n: int = 4096) -> list[dict]:
    """HBM bytes-accessed of the exact re-rank STAGE, gathered vs stream.

    The gathered path materializes a (Q, R, D) f32 copy of the candidate
    base rows (norms+GEMM formulation — already free of the broadcast-
    subtraction intermediate) before top-k; the streamed path
    (``ops.rerank_stream_topk``) DMAs only the candidate rows out of the
    in-place base and reduces to (Q, k) in VMEM. Compiled-only
    (cost_analysis needs no execution), so this runs at the acceptance
    shape (Q=32, k=10, r=4, D=128) even in the CI smoke job. The gathered
    number grows with N (XLA charges the row gather against the whole
    table); the stream number does not — the base is an in-place operand
    the kernel only ever touches R rows of.
    """
    rng = np.random.default_rng(0)
    rr = r * k
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = base_norms(base)
    qs = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    cand = jnp.asarray(rng.integers(0, n, (q, rr)).astype(np.int32))
    stages = (
        ("gathered", jax.jit(functools.partial(rerank_mod.exact_rerank, k=k)),
         (base, qs, cand), {"norms": norms}),
        ("stream", jax.jit(functools.partial(ops.rerank_stream_topk, k=k)),
         (base, norms, qs, cand), {}),
    )
    records = []
    for name, fn, args, kw in stages:
        cost = xla_cost_dict(fn.lower(*args, **kw).compile())
        rec = {"kernel": "rerank_stage", "impl": name, "Q": q, "k": k,
               "r": r, "D": d, "N": n,
               "bytes_accessed": cost.get("bytes accessed", 0.0),
               "backend": jax.default_backend()}
        records.append(rec)
        common.emit(f"rerank_stage_bytes_{name}", 0.0,
                    f"bytes_accessed={rec['bytes_accessed']:.0f}")
    if records[1]["bytes_accessed"]:
        ratio = records[0]["bytes_accessed"] / records[1]["bytes_accessed"]
        common.emit("rerank_stage_traffic_ratio", 0.0,
                    f"gathered/stream={ratio:.1f}x (acceptance: >= 4x)")
    return records


def main() -> None:
    rng = np.random.default_rng(0)
    q_, n_, m_ = 8, 65536, 16
    table = jnp.asarray(rng.integers(0, 256, (q_, m_, 16), np.uint8))
    packed = jnp.asarray(rng.integers(0, 256, (n_, m_ // 2), np.uint8))

    for impl in ("ref", "select", "mxu"):
        t = common.time_call(ops.fastscan_distances, table, packed, impl=impl)
        common.emit(f"kernel_{impl}_Q{q_}_N{n_}_M{m_}", t / q_,
                    "interpret-mode wall clock (CPU correctness path)")

    records = (grouped_sweep() + scan_stage_traffic()
               + anytime_scan_traffic() + rerank_stage_traffic())
    with open(KERNELS_JSON, "w") as f:
        json.dump({"schema": "repro.kernel_bench/v1", "records": records}, f,
                  indent=2)
    common.emit("kernel_grouped_json", 0.0,
                f"wrote {len(records)} records to {KERNELS_JSON}")

    t_min = common.time_call(ops.fastscan_blockmin, table, packed, block=1024)
    common.emit(f"kernel_blockmin_Q{q_}_N{n_}_M{m_}", t_min / q_,
                "fused scan+min (movemask analogue)")

    model = roofline_model(m=m_, n=10**6)
    common.emit("kernel_roofline_naivePQ_1M", model["naive_t"],
                "v5e model: memory-LUT gather path")
    common.emit("kernel_roofline_fastscan_1M", model["fast_t"],
                f"v5e model: register LUT; speedup={model['naive_t']/model['fast_t']:.1f}x")
    common.emit("kernel_roofline_mxu_1M", model["mxu_t"],
                f"v5e model: one-hot MXU; speedup={model['naive_t']/model['mxu_t']:.1f}x")


if __name__ == "__main__":
    main()
